"""Compressed optimizer state: bf16-hi + seeded stochastic rounding
(repro/optim/stochastic.py, the ``*_bf16`` RowOptimizers) and the
register-only optimizer flow (the PR-5 registration refactor).

Contracts under test:
* The dither helpers: pure function of (seed, row, lane) — bitwise
  reproducible, seed-sensitive — and UNBIASED: the mean rounding error
  over many seeds vanishes where plain truncation biases toward zero.
* Seeded determinism: for one per-step seed the reference scan, the
  fused device-sorted kernel and the host-pre-sorted stream produce
  BITWISE-identical stores (weights AND compressed state) over a
  multi-step trajectory; changing the seed changes the stored state.
* Trajectory: ``momentum_bf16`` stays within a pinned tolerance of fp32
  ``momentum`` over 50 steps on a zipf lookup stream.
* Register-only flow: a toy optimizer registered HERE (its own Pallas
  kernel body + reference hook, ``register()`` only) runs the pipelined
  train step end-to-end with zero edits to ``kernels/ops.py``,
  ``core/sharded_embedding.py`` or ``core/pipeline.py`` — and a source
  scan proves those modules carry no per-optimizer dispatch to edit.
"""

import ast
import os

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp

from repro.optim import row
from repro.optim.stochastic import mix32, sr_noise, sr_round_bf16

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
BF16_OPTS = ("momentum_bf16", "adagrad_bf16")


def _mk(M=60, E=16, B=8, S=2, P=3, vocab=None, seed=0):
    rng = np.random.default_rng(seed)
    W = jnp.asarray(rng.standard_normal((M, E)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, vocab or M, (B, S, P)), jnp.int32)
    dY = jnp.asarray(rng.standard_normal((B, S, E)), jnp.float32)
    return W, idx, dY


def _np_store(store):
    return {k: np.asarray(v, np.float32) if v.dtype == jnp.bfloat16
            else np.asarray(v) for k, v in store.items()}


# ---------------------------------------------------------------------------
# The dither helpers
# ---------------------------------------------------------------------------

def test_noise_is_pure_counter_function():
    """Same (seed, rows, width) => identical bits; any counter change =>
    different stream (the property that makes the three update paths
    agree without sharing sampler state)."""
    rows = jnp.asarray([0, 3, 3, 17], jnp.int32)
    a = np.asarray(sr_noise(7, rows, 8))
    assert a.shape == (4, 8) and a.dtype == np.uint32
    np.testing.assert_array_equal(a, np.asarray(sr_noise(7, rows, 8)))
    assert not np.array_equal(a, np.asarray(sr_noise(8, rows, 8)))
    # duplicate row ids draw duplicate noise (row identity, not position)
    np.testing.assert_array_equal(a[1], a[2])
    assert not np.array_equal(a[0], a[1])
    # lanes decorrelated: 3 distinct rows x 8 lanes = 24 distinct words
    assert len(np.unique(a)) == 24
    assert mix32(jnp.uint32(0)).dtype == jnp.uint32


def test_stochastic_round_unbiased_and_bounded():
    """Mean rounding error over many seeds ~ 0 (well under the one-ulp
    truncation bias); every draw lands on one of the two bf16 neighbours."""
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((64,)) * 10.0 ** rng.integers(
        -3, 4, (64,)), jnp.float32)
    rows = jnp.zeros((), jnp.int32)   # one row id, 64 lanes
    bits = np.asarray(x).view(np.uint32)
    lo32 = ((bits >> 16) << 16).view(np.float32)        # truncation
    hi32 = (((bits >> 16) + 1) << 16).view(np.float32)  # next bf16 outward
    ulp = np.abs(hi32.astype(np.float64) - lo32.astype(np.float64))
    n_seeds = 400
    acc = np.zeros(64, np.float64)
    for s in range(n_seeds):
        rf = np.asarray(sr_round_bf16(x, sr_noise(s, rows, 64)), np.float32)
        # each draw is one of the two neighbours
        assert np.all((rf == lo32) | (rf == hi32))
        acc += rf
    mean_err = np.abs(acc / n_seeds - np.asarray(x, np.float64))
    # statistical bound: std <= 0.5*ulp/sqrt(N) ~ 0.025 ulp; 0.2 is ~8 sigma
    assert np.max(mean_err / ulp) < 0.2
    # plain truncation is biased by the dropped mantissa half (sanity:
    # SR beats it by an order of magnitude on average)
    trunc_err = np.abs(lo32.astype(np.float64) - np.asarray(x, np.float64))
    assert np.mean(mean_err) < 0.1 * np.mean(trunc_err)


def test_exact_bf16_values_round_trip_unchanged():
    """A value already representable in bf16 has zero discarded bits: every
    seed must store it EXACTLY (dither < 1 shifts nothing)."""
    x = jnp.asarray([1.0, -2.5, 0.0, 384.0], jnp.float32)
    for s in (0, 1, 12345):
        out = np.asarray(sr_round_bf16(x, sr_noise(s, jnp.zeros((), jnp.int32),
                                                   4)), np.float32)
        np.testing.assert_array_equal(out, np.asarray(x))


# ---------------------------------------------------------------------------
# Seeded determinism across the three update paths
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", BF16_OPTS)
def test_three_paths_bitwise_identical_per_seed(name):
    """reference scan == fused device-sort == host-pre-sorted, BITWISE
    (weights and compressed state), over a 3-step duplicate-heavy
    trajectory with per-step seeds — and rerunning with the same seeds
    reproduces the bits."""
    from repro.kernels.embedding_update import sort_lookups
    M, E, P = 60, 16, 3
    W, idx, dY = _mk(M=M, E=E, P=P, vocab=7, seed=1)
    opt = row.get(name)
    st0 = opt.init_store(W)
    ref = jax.jit(lambda s, i, d, sd: opt.apply_sparse(
        s, row.SparseStream(idx=i, dY=d), 0.05, seed=sd, fused=False))
    sort = jax.jit(lambda t: sort_lookups(t, None, M, P))

    def run(mode):
        st = dict(st0)
        for i in range(3):
            d = dY * (i + 1)
            if mode == "ref":
                st = ref(st, idx, d, i)
            elif mode == "fused":
                st = opt.apply_sparse(st, row.SparseStream(idx=idx, dY=d),
                                      0.05, seed=i, fused=True,
                                      interpret=True)
            else:
                st = opt.apply_sparse(
                    st, row.SparseStream(presort=sort(idx.reshape(-1)),
                                         dY=d.reshape(-1, E)),
                    0.05, seed=i, interpret=True)
        return _np_store(st)

    a, b, c = run("ref"), run("fused"), run("presort")
    for k in a:
        np.testing.assert_array_equal(a[k], b[k], err_msg=f"{k} ref/fused")
        np.testing.assert_array_equal(b[k], c[k],
                                      err_msg=f"{k} fused/presort")
    b2 = run("fused")
    for k in b:
        np.testing.assert_array_equal(b[k], b2[k], err_msg=f"{k} rerun")


@pytest.mark.parametrize("name", BF16_OPTS)
def test_seed_changes_stored_state(name):
    """Different per-step seeds => different stored state bits (the dither
    actually reaches the slab); the fp32 weight slab is seed-independent
    on the FIRST step (state decoded from zeros, rounding only affects
    what the next step sees)."""
    W, idx, dY = _mk(vocab=7, seed=2)
    opt = row.get(name)
    st0 = opt.init_store(W)
    stream = row.SparseStream(idx=idx, dY=dY)
    s1 = opt.apply_sparse(dict(st0), stream, 0.05, seed=0, fused=True,
                          interpret=True)
    s2 = opt.apply_sparse(dict(st0), stream, 0.05, seed=123, fused=True,
                          interpret=True)
    (k,) = opt.state_keys
    assert not np.array_equal(np.asarray(s1[k], np.float32),
                              np.asarray(s2[k], np.float32))
    np.testing.assert_array_equal(np.asarray(s1["w"]), np.asarray(s2["w"]))


def test_masked_runs_never_touch_compressed_state():
    """All-masked streams are exact no-ops on weights AND bf16 state, both
    paths (the SMEM liveness flag / reference drop both apply before any
    rounding)."""
    W, idx, dY = _mk(vocab=6, seed=3)
    opt = row.get("momentum_bf16")
    st = dict(opt.init_store(W))
    st["mom"] = jnp.full_like(st["mom"], jnp.bfloat16(0.5))
    masked = row.SparseStream(idx=idx, dY=dY,
                              valid=jnp.zeros(idx.shape, bool))
    for out in (opt.apply_sparse(st, masked, 0.05, seed=9, fused=True,
                                 interpret=True),
                jax.jit(lambda s, t: opt.apply_sparse(s, t, 0.05, seed=9,
                                                      fused=False))(st,
                                                                    masked)):
        np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(W))
        np.testing.assert_array_equal(
            np.asarray(out["mom"], np.float32),
            np.asarray(st["mom"], np.float32))


# ---------------------------------------------------------------------------
# Trajectory: compressed momentum tracks fp32 momentum
# ---------------------------------------------------------------------------

def test_momentum_bf16_tracks_fp32_over_50_zipf_steps():
    """50 steps on a zipf stream: the compressed-state trajectory stays
    within a PINNED tolerance of the fp32 momentum trajectory — the
    unbiased dither accumulates as a random walk, not a drift.  The pin
    (2% of the total weight displacement, max-norm) has ~4x headroom
    over the observed value; loosening it is a regression."""
    from repro.data.synthetic import zipf_indices
    rng = np.random.default_rng(0)
    M, E, B, S, P = 2000, 32, 64, 1, 4
    W = jnp.asarray(rng.standard_normal((M, E)) * 0.1, jnp.float32)
    fp = row.get("momentum")
    bf = row.get("momentum_bf16")
    assert fp.beta == bf.beta
    st_fp = fp.init_store(W)
    st_bf = bf.init_store(W)
    step_fp = jax.jit(lambda s, i, d: fp.apply_sparse(
        s, row.SparseStream(idx=i, dY=d), 0.05, fused=False))
    step_bf = jax.jit(lambda s, i, d, sd: bf.apply_sparse(
        s, row.SparseStream(idx=i, dY=d), 0.05, seed=sd, fused=False))
    for t in range(50):
        idx = jnp.asarray(zipf_indices(rng, M, (B, S, P), 1.1).astype(
            np.int32))
        dY = jnp.asarray(rng.standard_normal((B, S, E)), jnp.float32)
        st_fp = step_fp(st_fp, idx, dY)
        st_bf = step_bf(st_bf, idx, dY, t)
    w_fp = np.asarray(st_fp["w"], np.float64)
    w_bf = np.asarray(st_bf["w"], np.float64)
    move = np.max(np.abs(w_fp - np.asarray(W, np.float64)))
    drift = np.max(np.abs(w_bf - w_fp))
    assert move > 0.1          # the stream actually trained something
    assert drift < 0.02 * move, (drift, move)


# ---------------------------------------------------------------------------
# Register-only optimizer flow + source scan
# ---------------------------------------------------------------------------

def _toy_kernel_body(rows_ref, bags_ref, msk_ref, hp_ref, wgt_ref, w_ref,
                     s_ref, dY_ref, nw_ref, ns_ref, acc_ref, flg_ref):
    """Toy 'touch-count LR' rule: per touched row ``tc += 1``,
    ``w -= lr * g / sqrt(tc)`` — the frequency-adaptive shape that
    graduated into the first-class ``adagrad_freq`` optimizer and the
    reserved ``cnt`` touch-counter slab (repro/optim/row.py), kept here
    cut down to a registration-flow probe.  The state key is ``tc`` (not
    ``cnt``) on purpose: ``cnt`` now has reserved generic bump semantics
    in ``apply_sparse`` and this toy owns its own counting."""
    import jax.experimental.pallas as pl
    from repro.kernels import embedding_update as ku
    i = pl.program_id(0)
    is_end = ku._accumulate_run(rows_ref, msk_ref, wgt_ref, dY_ref, acc_ref,
                                flg_ref, i)

    @pl.when(is_end)
    def _apply():
        live = flg_ref[0] != 0
        s_old = s_ref[...].astype(jnp.float32)
        s_new = s_old + 1.0
        w_old = w_ref[...].astype(jnp.float32)
        w_new = w_old - hp_ref[0] * acc_ref[...] / jnp.sqrt(s_new)
        ns_ref[...] = jnp.where(live, s_new, s_old).astype(ns_ref.dtype)
        nw_ref[...] = jnp.where(live, w_new, w_old).astype(nw_ref.dtype)


def _toy_kernel(opt, store, srows, sbags, smsk, swgt, dY, lr, seed, e_real,
                interpret):
    from repro.kernels import embedding_update as ku
    hp = jnp.stack([jnp.asarray(lr, jnp.float32),
                    jnp.zeros((), jnp.float32),
                    jnp.zeros((), jnp.float32)])
    nw, ns = ku._stateful_call(_toy_kernel_body, store["w"], store["tc"],
                               srows, sbags, smsk, swgt, dY, hp, interpret)
    return {"w": nw, "tc": ns}


def _toy_reference(opt, store, rep, summed, lr, seed):
    W = store["w"]
    safe = jnp.minimum(rep, W.shape[0] - 1)
    s_new = jnp.take(store["tc"], safe, axis=0) + 1.0
    w_new = jnp.take(W, safe, axis=0) - lr * summed / jnp.sqrt(s_new)
    return {"w": W.at[rep].set(w_new),
            "tc": store["tc"].at[rep].set(s_new)}


def test_toy_optimizer_register_only_flow():
    """Acceptance: a toy optimizer registered HERE — one kernel body +
    ``register()`` — runs the pipelined train step end-to-end (fused
    kernel AND reference path), with NO edits to kernels/ops.py,
    core/sharded_embedding.py or core/pipeline.py."""
    import dataclasses
    from repro.core.dlrm import DLRMConfig, init_state, make_train_step
    from repro.launch.mesh import make_mesh

    row.register(row.RowOptimizer(name="toy_counter", state=(("tc", 0),),
                                  kernel=_toy_kernel,
                                  reference=_toy_reference))
    try:
        mesh = make_mesh((1, 1), ("data", "model"))
        rng = np.random.default_rng(0)
        batch = {
            "idx": jnp.asarray(np.stack(
                [rng.integers(0, max(2, m // 6), (16, 3))
                 for m in (50, 30, 20, 10)], 1).astype(np.int32)),
            "dense_x": jnp.asarray(rng.standard_normal((16, 8)),
                                   jnp.bfloat16),
            "labels": jnp.asarray(rng.integers(0, 2, (16,)), jnp.float32),
        }
        results = {}
        layout = None
        for fused in (True, False):
            cfg = DLRMConfig(name="t", num_dense=8, bottom=(16, 8),
                             top=(16,), table_rows=(50, 30, 20, 10),
                             emb_dim=8, pooling=3, batch=16,
                             sparse_optimizer="toy_counter",
                             fused_update=fused)
            state, layout = init_state(jax.random.PRNGKey(0), cfg, mesh)
            step, _, _, _ = make_train_step(cfg, mesh)
            state, loss = step(state, batch)
            assert np.isfinite(float(loss))
            results[fused] = {k: np.asarray(v)
                              for k, v in state["emb"].items()}
        # touched rows in the GLOBAL row space (per-slot table offsets)
        touched = np.unique(np.asarray(batch["idx"])
                            + np.asarray(layout.row_offsets)[None, :, None])
        cnt = results[True]["tc"]
        # counter semantics: one global batch => every touched row at 1
        assert np.all(cnt[:, 0][np.isin(np.arange(cnt.shape[0]),
                                        touched, invert=True)] == 0)
        assert np.any(cnt == 1.0)
        # fused kernel vs reference scan agree on the toy math
        for k in results[True]:
            np.testing.assert_allclose(results[True][k], results[False][k],
                                       rtol=1e-6, atol=1e-7)
    finally:
        row.unregister("toy_counter")


def _code_strings(path):
    """All string constants in a module EXCLUDING docstrings."""
    with open(path) as f:
        tree = ast.parse(f.read())
    doc_ids = set()
    for node in ast.walk(tree):
        if isinstance(node, (ast.Module, ast.FunctionDef,
                             ast.AsyncFunctionDef, ast.ClassDef)):
            body = getattr(node, "body", [])
            if body and isinstance(body[0], ast.Expr) and isinstance(
                    body[0].value, ast.Constant) and isinstance(
                    body[0].value.value, str):
                doc_ids.add(id(body[0].value))
    return [n.value for n in ast.walk(tree)
            if isinstance(n, ast.Constant) and isinstance(n.value, str)
            and id(n) not in doc_ids]


def test_no_per_optimizer_dispatch_outside_registry():
    """Source scan: kernels/ops.py, core/sharded_embedding.py and
    core/pipeline.py contain NO optimizer-name string literals (no
    if-chains to edit when registering one) and ops.py references no
    specific kernel entry (the ``kernel`` hook owns that)."""
    files = [os.path.join(SRC, "repro", "kernels", "ops.py"),
             os.path.join(SRC, "repro", "core", "sharded_embedding.py"),
             os.path.join(SRC, "repro", "core", "pipeline.py")]
    names = set(row.names()) | {"toy_counter"}
    for path in files:
        for s in _code_strings(path):
            for name in names:
                assert name not in s, (path, name, s)
    ops_src = open(files[0]).read()
    assert "fused_update_" not in ops_src   # kernel entries live on hooks
    assert ".kind" not in ops_src           # the old dispatch key is gone
