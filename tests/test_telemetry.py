"""Telemetry stack: tracer, histogram, in-graph metrics, stage profile,
summarize, heartbeat.  The load-bearing contracts:

* disabled tracer = shared no-op span, zero events (safe to leave wired
  into every hot path);
* ``step_metrics=True`` is bitwise invisible to training and its drained
  window reproduces the cache bench's hit-rate arithmetic exactly;
* the stage profiler emits one span per pipeline stage with modeled
  bytes/flops;
* the train-loop heartbeat JSONL carries step percentiles, the straggler
  snapshot, ingest stats and the metrics window.
"""

import json
import threading
import time

import numpy as np
import pytest

from repro import telemetry
from repro.telemetry import LatencyHistogram, Tracer
from repro.telemetry import metrics as step_mx
from repro.telemetry.summarize import summarize
from repro.telemetry.tracer import _NOOP_SPAN


# ---------------------------------------------------------------------------
# Tracer
# ---------------------------------------------------------------------------


def test_disabled_tracer_is_noop():
    tr = Tracer(enabled=False)
    s1 = tr.span("a", step=1)
    s2 = tr.span("b")
    assert s1 is _NOOP_SPAN and s2 is _NOOP_SPAN  # shared singleton
    with s1:
        pass
    tr.instant("x")
    tr.counter("c", {"v": 1.0})
    assert tr.events() == []


def test_span_events_and_nesting():
    tr = Tracer(enabled=True)
    with tr.span("outer", cat="t", step=3):
        with tr.span("inner"):
            time.sleep(0.002)
    evs = [e for e in tr.events() if e["ph"] == "X"]
    by = {e["name"]: e for e in evs}
    assert set(by) == {"outer", "inner"}
    assert by["outer"]["args"] == {"step": 3}
    assert by["outer"]["dur"] >= by["inner"]["dur"] > 0
    # inner nests inside outer on the same track
    assert by["inner"]["tid"] == by["outer"]["tid"]
    assert by["outer"]["ts"] <= by["inner"]["ts"]
    assert (by["inner"]["ts"] + by["inner"]["dur"]
            <= by["outer"]["ts"] + by["outer"]["dur"] + 1.0)


def test_tracks_thread_names_and_virtual(tmp_path):
    tr = Tracer(enabled=True, trace_dir=str(tmp_path))
    tr.set_track("train_loop")
    with tr.span("step"):
        pass
    with tr.span("stage/x", track="pipeline_stages"):
        pass

    def worker():
        with tr.span("pull"):
            pass

    t = threading.Thread(target=worker, name="ingest_worker")
    t.start()
    t.join()
    tr.instant("fault/test", track="faults")
    path = tr.export()
    doc = json.loads(path.read_text())
    names = {e["args"]["name"] for e in doc["traceEvents"]
             if e.get("ph") == "M"}
    assert {"train_loop", "pipeline_stages", "ingest_worker",
            "faults"} <= names
    assert "epoch_unix_s" in doc["otherData"]


def test_tracer_thread_safety():
    tr = Tracer(enabled=True)

    def emit(i):
        for j in range(200):
            with tr.span(f"t{i}", j=j):
                pass

    ts = [threading.Thread(target=emit, args=(i,)) for i in range(8)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    spans = [e for e in tr.events() if e["ph"] == "X"]
    assert len(spans) == 8 * 200


def test_global_configure_round_trip(tmp_path):
    tr = telemetry.configure(enabled=True, trace_dir=str(tmp_path))
    try:
        with telemetry.span("g"):
            pass
        assert any(e.get("name") == "g" for e in tr.events())
    finally:
        telemetry.configure(enabled=False)
        tr.reset()
    assert telemetry.span("after") is _NOOP_SPAN


# ---------------------------------------------------------------------------
# LatencyHistogram
# ---------------------------------------------------------------------------


def test_latency_histogram_quantiles():
    h = LatencyHistogram()
    assert h.summary() == {}
    vals = np.linspace(1.0, 100.0, 1000)
    for v in vals:
        h.record(float(v))
    s = h.summary()
    assert s["n"] == 1000
    # log-bucketed: 2% relative resolution
    assert s["p50"] == pytest.approx(np.percentile(vals, 50), rel=0.05)
    assert s["p99"] == pytest.approx(np.percentile(vals, 99), rel=0.05)
    assert s["min"] <= s["p50"] <= s["p99"] <= s["max"]
    assert s["mean"] == pytest.approx(vals.mean(), rel=0.05)


def test_latency_histogram_out_of_range_samples():
    """Samples outside [lo, hi) are clamped into the edge buckets but stay
    EXACT in min/max/mean — recording them must never raise or be lost."""
    h = LatencyHistogram(lo=1.0, hi=100.0, growth=1.02)
    h.record(0.001)     # far below lo -> first bucket
    h.record(0.5)
    h.record(10.0)
    h.record(5000.0)    # far above hi -> last bucket
    s = h.summary()
    assert s["n"] == 4
    assert s["min"] == 0.001 and s["max"] == 5000.0
    assert s["mean"] == pytest.approx((0.001 + 0.5 + 10.0 + 5000.0) / 4)
    # quantiles stay inside the observed range; an above-hi sample
    # saturates at the last bucket, so its quantile caps near hi (the
    # exact value survives only in min/max/mean)
    assert s["min"] <= h.quantile(0.0) <= h.quantile(1.0) <= s["max"]
    assert 100.0 <= h.quantile(1.0) <= 105.0


def test_latency_histogram_single_sample():
    h = LatencyHistogram()
    h.record(42.0)
    s = h.summary()
    assert s["n"] == 1
    assert s["min"] == s["max"] == s["mean"] == 42.0
    # with one sample every quantile is that sample (clamping to the
    # exact min/max beats the bucket midpoint)
    assert s["p50"] == s["p99"] == 42.0


def test_latency_histogram_relative_error_bound():
    """Documented accuracy contract: with growth=1.02 any quantile of a
    known distribution is within 2% relative error (bucket width + the
    'lower' rank convention's one-sample slack)."""
    rng = np.random.default_rng(7)
    vals = rng.lognormal(mean=1.0, sigma=1.5, size=20_000)
    h = LatencyHistogram(lo=1e-3, hi=1e5, growth=1.02)
    for v in vals:
        h.record(float(v))
    svals = np.sort(vals)
    for q in (0.01, 0.25, 0.5, 0.9, 0.99):
        exact = svals[int(q * len(svals))]      # 'lower' rank convention
        assert h.quantile(q) == pytest.approx(exact, rel=0.02), q


def test_serve_loop_uses_histogram():
    from repro.serve import BatchingServer

    server = BatchingServer(lambda b: np.zeros(4), batch_size=4,
                            pad_batch=lambda reqs: {"n": len(reqs)})
    assert server.percentiles() == {}
    for i in range(10):
        server.submit(i)
    list(server.drain())
    p = server.percentiles()
    assert p["n"] == 10
    assert 0 < p["p50_ms"] <= p["p99_ms"]


# ---------------------------------------------------------------------------
# Metrics: host-side helpers
# ---------------------------------------------------------------------------


def test_metrics_pack_window_hit_rate():
    import jax.numpy as jnp

    v = step_mx.pack(steps=1.0, bags=4.0, skipped_bags=3.0)
    assert v.shape == (step_mx.NUM_METRICS,)
    assert float(v[step_mx.METRIC_NAMES.index("bags")]) == 4.0
    with pytest.raises(ValueError):
        step_mx.pack(nope=1.0)
    cur = dict(zip(step_mx.METRIC_NAMES, [2.0, 0.0, 6.0, 8.0, 10.0, 64.0]))
    prev = dict(zip(step_mx.METRIC_NAMES, [1.0, 0.0, 3.0, 4.0, 5.0, 32.0]))
    win = step_mx.window(cur, prev)
    assert win["bags"] == 4.0 and win["skipped_bags"] == 3.0
    # f32 arithmetic, same as jnp.mean over the hit mask
    assert step_mx.hit_rate(win) == float(jnp.float32(3.0) / jnp.float32(4.0))
    assert step_mx.hit_rate({"bags": 0.0}) == 0.0
    assert step_mx.drain({"no": 1}) is None
    assert step_mx.drain(object()) is None


def _small_cfg(**kw):
    from repro.core.dlrm import DLRMConfig

    base = dict(name="t", num_dense=8, bottom=(16, 8), top=(16,),
                table_rows=(64, 48, 32), emb_dim=8, pooling=3, batch=8,
                emb_mode="table", idx_input="sharded")
    base.update(kw)
    return DLRMConfig(**base)


def _draw_idx(rng, cfg, zipf=None):
    if zipf is not None:
        from repro.data.synthetic import zipf_indices

        cols = [zipf_indices(rng, m, (cfg.batch, cfg.pooling), zipf)
                for m in cfg.table_rows]
    else:
        cols = [rng.integers(0, m, (cfg.batch, cfg.pooling))
                for m in cfg.table_rows]
    return np.stack(cols, 1).astype(np.int32)


def _run_steps(cfg, n, seed=0, zipf=None):
    import jax
    import jax.numpy as jnp

    from repro.core.dlrm import init_state, make_train_step
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((1, 1), ("data", "model"))
    step, _, _, layout = make_train_step(cfg, mesh)
    state, _ = init_state(jax.random.PRNGKey(0), cfg, mesh)
    rng = np.random.default_rng(seed)
    losses = []
    batches = []
    for _ in range(n):
        idx = _draw_idx(rng, cfg, zipf)
        b = {"idx": jnp.asarray(idx),
             "dense_x": jnp.asarray(
                 rng.standard_normal((cfg.batch, cfg.num_dense)),
                 jnp.bfloat16),
             "labels": jnp.asarray(rng.integers(0, 2, cfg.batch),
                                   jnp.float32)}
        batches.append(b)
        state, loss = step(state, b)
        losses.append(np.asarray(loss))
    return state, losses, layout, batches


def test_step_metrics_bitwise_invisible_and_exact_counts():
    off_state, off_losses, _, _ = _run_steps(_small_cfg(), 3)
    on_state, on_losses, _, _ = _run_steps(_small_cfg(step_metrics=True), 3)
    assert "metrics" not in off_state and "metrics" in on_state
    for a, b in zip(off_losses, on_losses):
        assert a.tobytes() == b.tobytes()  # bitwise, not approx
    import jax

    for k in off_state:
        la = jax.tree_util.tree_leaves(off_state[k])
        lb = jax.tree_util.tree_leaves(on_state[k])
        for a, b in zip(la, lb):
            assert np.asarray(a).tobytes() == np.asarray(b).tobytes(), k
    m = step_mx.drain(on_state)
    assert m["steps"] == 3.0
    # every index was drawn in-range: rows = batch * slots * pooling / step
    assert m["rows_touched"] == 3 * 8 * 3 * 3
    assert m["bags"] == 3 * 8 * 3
    assert m["skipped_bags"] == 0.0  # no cache in this config
    assert m["exchange_payload_bytes"] == m["bags"] * 8 * 4


def test_cache_hit_metrics_match_hot_bag_local():
    import jax.numpy as jnp

    from repro.core import cache as hot_cache

    # 8 x 4 = 32 bags: a power of two, so the f32 divide in hit_rate and
    # jnp.mean's multiply-by-reciprocal are BOTH exact and must agree
    # bitwise (same reason the bench's 64 x 8 = 512 window is exact)
    cfg = _small_cfg(step_metrics=True, hot_rows=16, promote_every=2,
                     table_rows=(64, 48, 32, 32))
    state, _, layout, _ = _run_steps(cfg, 4, zipf=1.5)
    before = step_mx.drain(state)

    import jax

    from repro.core.dlrm import make_train_step
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((1, 1), ("data", "model"))
    step, _, _, _ = make_train_step(cfg, mesh)
    rng = np.random.default_rng(123)
    idx = _draw_idx(rng, cfg, zipf=1.5)
    b = {"idx": jnp.asarray(idx),
         "dense_x": jnp.asarray(
             rng.standard_normal((cfg.batch, cfg.num_dense)), jnp.bfloat16),
         "labels": jnp.asarray(rng.integers(0, 2, cfg.batch), jnp.float32)}
    # the bench measurement: all-hot-bag fraction on this batch against
    # the PRE-step hot set
    hit, _ = hot_cache.hot_bag_local(layout, state["cache"]["hot_w"],
                                     state["cache"]["hot_pos"], b["idx"])
    bench_rate = float(jnp.mean(hit))
    state, _ = step(state, b)
    jax.block_until_ready(state["metrics"])
    win = step_mx.window(step_mx.drain(state), before)
    assert win["steps"] == 1.0
    assert win["bags"] == cfg.batch * len(cfg.table_rows)
    assert step_mx.hit_rate(win) == bench_rate  # exact, not approx
    # zipf(1.5) + hot 16 of <=64 rows: a real hit rate, not trivially 0/1
    assert 0 < win["skipped_bags"] < win["bags"]


# ---------------------------------------------------------------------------
# Stage profiler
# ---------------------------------------------------------------------------


def test_profile_stages_emits_all_six_stages():
    from repro.core.dlrm import as_hybrid_def
    from repro.telemetry import stages as stage_prof

    tr = Tracer(enabled=True)
    out = stage_prof.profile_stages(as_hybrid_def(_small_cfg()), tracer=tr,
                                    steps=2, warmup=1)
    expect = {"index_exchange", "embedding_fwd", "dense_fwd_bwd",
              "dY_exchange", "sparse_update", "dense_update"}
    assert set(out["stages"]) == expect
    spans = [e for e in tr.events() if e.get("ph") == "X"]
    assert {e["name"] for e in spans} == {f"stage/{s}" for s in expect}
    assert all(e["args"]["modeled_bytes"] > 0 for e in spans)
    for rec in out["stages"].values():
        assert rec["ms"] > 0
        assert rec["bytes"] > 0 and rec["modeled_us"] >= 0
    # spans land on the virtual pipeline_stages track
    meta = {e["args"]["name"] for e in tr.events() if e.get("ph") == "M"}
    assert "pipeline_stages" in meta


def test_modeled_stage_costs_cover_stages():
    from repro.core.dlrm import as_hybrid_def
    from repro.telemetry.stages import modeled_stage_costs

    costs = modeled_stage_costs(as_hybrid_def(_small_cfg()))
    assert {"index_exchange", "embedding_fwd", "dense_fwd_bwd",
            "dY_exchange", "sparse_update", "dense_update"} <= set(costs)
    for rec in costs.values():
        assert rec["bytes"] >= 0 and rec["flops"] >= 0
        assert rec["modeled_us"] >= 0


# ---------------------------------------------------------------------------
# Summarize
# ---------------------------------------------------------------------------


def test_summarize_round_trip(tmp_path):
    tr = Tracer(enabled=True, trace_dir=str(tmp_path))
    tr.set_track("train_loop")
    for i in range(3):
        with tr.span("train/step", step=i):
            time.sleep(0.001)
    tr.instant("fault/skip", track="faults")
    step_mx.emit(tr, dict(zip(step_mx.METRIC_NAMES,
                              [1.0, 0.0, 0.0, 4.0, 8.0, 128.0])))
    step_mx.emit(tr, dict(zip(step_mx.METRIC_NAMES,
                              [2.0, 0.0, 3.0, 8.0, 16.0, 160.0])))
    s = summarize(tr.export())
    row = s["tracks"]["train_loop"]["train/step"]
    assert row["count"] == 3 and row["total_ms"] >= 3.0
    assert s["instants"] == {"fault/skip": 1}
    m = s["metrics"]
    assert m["drains"] == 2
    assert m["last_window"]["bags"] == 4.0
    assert m["last_window"]["skipped_bags"] == 3.0
    assert m["last_window_hit_rate"] == step_mx.hit_rate(m["last_window"])


def test_summarize_aggregates_serve_spans(tmp_path):
    """`summarize` folds serve/* spans into a serve section with a
    per-bucket breakdown (batches, requests, wall time)."""
    tr = Tracer(enabled=True, trace_dir=str(tmp_path))
    tr.set_track("serve_worker")
    for bucket, n in ((8, 5), (8, 8), (32, 20)):
        with tr.span("serve/batch", cat="serve", bucket=bucket, n=n,
                     queue_depth=0):
            time.sleep(0.001)
    tr.instant("serve/publish", cat="serve", step=4, version=2)
    s = summarize(tr.export())
    row = s["serve"]["serve/batch"]
    assert row["count"] == 3 and row["requests"] == 33
    assert row["by_bucket"]["8"] == pytest.approx(
        {"count": 2, "requests": 13,
         "total_ms": row["by_bucket"]["8"]["total_ms"],
         "mean_ms": row["by_bucket"]["8"]["total_ms"] / 2})
    assert row["by_bucket"]["32"]["requests"] == 20
    assert s["instants"]["serve/publish"] == 1
    # non-serving traces keep an empty section
    assert summarize(Tracer(enabled=True,
                            trace_dir=str(tmp_path)).export())["serve"] == {}


def test_summarize_cli(tmp_path, capsys):
    from repro.telemetry.summarize import main

    tr = Tracer(enabled=True, trace_dir=str(tmp_path))
    with tr.span("x"):
        pass
    p = tr.export()
    assert main(["summarize", str(p)]) == 0
    out = capsys.readouterr().out
    assert "x" in out and "track:" in out
    assert main(["summarize", str(p), "--json"]) == 0
    json.loads(capsys.readouterr().out)


# ---------------------------------------------------------------------------
# StragglerMonitor snapshot + heartbeat
# ---------------------------------------------------------------------------


def test_straggler_snapshot_flags_synthetic_slow_step():
    from repro.train import StragglerMonitor

    mon = StragglerMonitor(window=50, threshold=2.0)
    assert mon.snapshot() == {"n": 0, "outliers": 0}
    for i in range(20):
        mon.record(i, 0.010)
    assert mon.record(20, 0.100)  # 10x median -> straggler
    snap = mon.snapshot()
    assert snap["n"] == 21 and snap["outliers"] == 1
    assert snap["median_ms"] == pytest.approx(10.0)
    assert snap["max_ms"] == pytest.approx(100.0)
    assert snap["p99_ms"] > snap["median_ms"]


def test_trainloop_heartbeat_jsonl(tmp_path):
    from repro.data.pipeline import ThreadedIterator
    from repro.train import TrainLoop, TrainLoopConfig

    def step(state, batch):
        time.sleep(0.001)
        return state + batch, float(batch)

    hb = tmp_path / "heartbeat.jsonl"
    stream = ThreadedIterator(iter(range(100)), depth=2)
    loop = TrainLoop(
        TrainLoopConfig(steps=7, heartbeat_path=str(hb), heartbeat_every=3,
                        log_every=100),
        step, 0, stream)
    loop.run()
    stream.close()
    recs = [json.loads(line) for line in hb.read_text().splitlines()]
    # windows at steps 3 and 6, plus the final flush at 7
    assert [r["step"] for r in recs] == [3, 6, 7]
    for r in recs[:2]:
        assert r["window_steps"] == 3
        assert 0 < r["step_ms_p50"] <= r["step_ms_p99"]
        assert r["straggler"]["n"] >= 3
        assert r["ingest"]["batches"] >= 3  # reads the iterator's stats
        assert r["skipped_batches"] == 0
    assert recs[-1]["window_steps"] == 1


def test_trainloop_emits_step_spans_and_closes_prefetch(tmp_path):
    from repro.train import TrainLoop, TrainLoopConfig

    tr = telemetry.configure(enabled=True)
    try:
        def step(state, batch):
            return state, 0.5

        loop = TrainLoop(
            TrainLoopConfig(steps=4, prefetch=2, log_every=100),
            step, 0, iter(np.arange(50.0)))
        loop.run()
        spans = [e for e in tr.events()
                 if e.get("ph") == "X" and e["name"] == "train/step"]
        assert len(spans) == 4
        # the loop owns the prefetch wrapper it created and closed it
        assert loop._owns_batches
        assert not loop.batches._tit._thread.is_alive()
    finally:
        telemetry.configure(enabled=False)
        tr.reset()
