"""Train loop fault tolerance: straggler detection, data rebalancing,
checkpoint/restore mid-run."""

import numpy as np

from repro.train import StragglerMonitor, TrainLoop, TrainLoopConfig
from repro.train.loop import DataRebalancer


def test_straggler_detection():
    mon = StragglerMonitor(window=20, threshold=2.0)
    for i in range(20):
        assert not mon.record(i, 0.1)
    assert mon.record(20, 0.5)          # 5x median
    assert not mon.record(21, 0.12)
    assert len(mon.events) == 1


def test_straggler_callback():
    hits = []
    mon = StragglerMonitor(window=10, threshold=1.5,
                           on_straggler=lambda s, dt, med: hits.append(s))
    for i in range(12):
        mon.record(i, 0.1)
    mon.record(99, 1.0)
    assert hits == [99]


def test_rebalancer_conserves_batch():
    rb = DataRebalancer(n_hosts=4)
    rb.penalize(2)
    rb.penalize(2)
    rows = rb.rows_per_host(1024)
    assert rows.sum() == 1024
    assert rows[2] < rows[0]
    # floor: repeated penalties never starve a host below min_share
    for _ in range(50):
        rb.penalize(2)
    assert rb.rows_per_host(1024)[2] >= int(0.5 / 4 * 1024) - 1


def test_loop_checkpoint_restore(tmp_path):
    def step(state, batch):
        return state + 1, float(state)

    batches = iter(range(10_000))
    loop = TrainLoop(TrainLoopConfig(steps=10, ckpt_dir=str(tmp_path),
                                     ckpt_every=5, log_every=100),
                     step, 0, batches)
    loop.run()
    # a fresh loop restores and continues
    loop2 = TrainLoop(TrainLoopConfig(steps=15, ckpt_dir=str(tmp_path),
                                      ckpt_every=5, log_every=100),
                      step, 0, batches)
    assert loop2.start_step == 10
    assert int(loop2.state) == 10
    loop2.run()
    assert int(loop2.state) == 15
