"""Train loop fault tolerance: straggler detection, data rebalancing,
checkpoint/restore mid-run; host-side prefetch iterator ordering."""

import numpy as np
import pytest

from repro.train import (StragglerMonitor, TrainLoop, TrainLoopConfig,
                         prefetch_to_device)
from repro.train.loop import DataRebalancer


def test_straggler_detection():
    mon = StragglerMonitor(window=20, threshold=2.0)
    for i in range(20):
        assert not mon.record(i, 0.1)
    assert mon.record(20, 0.5)          # 5x median
    assert not mon.record(21, 0.12)
    assert len(mon.events) == 1


def test_straggler_callback():
    hits = []
    mon = StragglerMonitor(window=10, threshold=1.5,
                           on_straggler=lambda s, dt, med: hits.append(s))
    for i in range(12):
        mon.record(i, 0.1)
    mon.record(99, 1.0)
    assert hits == [99]


def test_rebalancer_conserves_batch():
    rb = DataRebalancer(n_hosts=4)
    rb.penalize(2)
    rb.penalize(2)
    rows = rb.rows_per_host(1024)
    assert rows.sum() == 1024
    assert rows[2] < rows[0]
    # floor: repeated penalties never starve a host below min_share
    for _ in range(50):
        rb.penalize(2)
    assert rb.rows_per_host(1024)[2] >= int(0.5 / 4 * 1024) - 1


class _RecordingIter:
    """Source iterator that records how far the consumer has pulled."""

    def __init__(self, n):
        self.n = n
        self.pulled = 0

    def __iter__(self):
        return self

    def __next__(self):
        if self.pulled >= self.n:
            raise StopIteration
        self.pulled += 1
        return {"x": np.full((2,), self.pulled - 1, np.int32)}


def test_prefetch_preserves_order_and_pulls_ahead():
    import time

    src = _RecordingIter(10)
    it = prefetch_to_device(src, size=3)
    first = next(it)
    # the worker thread runs ahead of the consumer, but never further than
    # the queue window (3) + the one batch it may hold while blocked on put
    deadline = time.monotonic() + 5.0
    while src.pulled < 4 and time.monotonic() < deadline:
        time.sleep(0.01)
    assert src.pulled >= 4                  # pulled ahead of the consumer
    assert src.pulled <= 1 + 3 + 1          # bounded-queue backpressure
    got = [int(np.asarray(first["x"])[0])]
    got += [int(np.asarray(b["x"])[0]) for b in it]
    assert got == list(range(10))           # order preserved exactly
    assert src.pulled == 10


class _FailingIter:
    """Yields ``good`` batches, then dies like a broken loader."""

    def __init__(self, good):
        self.good = good
        self.pulled = 0

    def __iter__(self):
        return self

    def __next__(self):
        if self.pulled >= self.good:
            raise RuntimeError("shard decode failed")
        self.pulled += 1
        return {"x": np.full((2,), self.pulled - 1, np.int32)}


def test_prefetch_propagates_worker_exception():
    """Satellite: a loader failure is re-raised at the consumer promptly
    (poisoned queue sentinel) instead of hanging the training loop."""
    it = prefetch_to_device(_FailingIter(2), size=4)
    assert int(np.asarray(next(it)["x"])[0]) == 0
    assert int(np.asarray(next(it)["x"])[0]) == 1
    with pytest.raises(RuntimeError, match="shard decode failed"):
        next(it)


def test_prefetch_early_exit_releases_worker():
    """Abandoning the iterator mid-stream (a step-bounded loop over an
    infinite source) closes the worker thread instead of leaking it
    blocked on the queue with device-resident batches."""
    import threading
    import time

    src = _RecordingIter(10_000)            # effectively endless
    it = prefetch_to_device(src, size=2)
    next(it), next(it)
    it.close()                              # generator finally -> close()
    deadline = time.monotonic() + 5.0
    while (any(t.name == "prefetch_to_device" and t.is_alive()
               for t in threading.enumerate())
           and time.monotonic() < deadline):
        time.sleep(0.01)
    assert not any(t.name == "prefetch_to_device" and t.is_alive()
                   for t in threading.enumerate())
    pulled = src.pulled
    time.sleep(0.05)
    assert src.pulled == pulled             # source is no longer drained


def test_loop_surfaces_loader_failure():
    """End-to-end: TrainLoop with prefetch fails fast on a dead loader."""
    def step(state, batch):
        return state + 1, float(state)

    loop = TrainLoop(TrainLoopConfig(steps=10, log_every=100, prefetch=2),
                     step, 0, _FailingIter(3))
    with pytest.raises(RuntimeError, match="shard decode failed"):
        loop.run()
    assert len(loop.losses) == 3            # the good batches did run


def test_prefetch_short_stream_and_validation():
    # stream shorter than the window still yields everything, in order
    src = _RecordingIter(2)
    got = [int(np.asarray(b["x"])[0]) for b in prefetch_to_device(src, 5)]
    assert got == [0, 1]
    with pytest.raises(ValueError, match="size"):
        list(prefetch_to_device(iter([]), size=0))


def test_loop_uses_prefetch():
    seen = []

    def step(state, batch):
        seen.append(int(np.asarray(batch["x"])[0]))
        return state + 1, float(state)

    loop = TrainLoop(TrainLoopConfig(steps=6, log_every=100, prefetch=2),
                     step, 0, _RecordingIter(100))
    loop.run()
    assert seen == list(range(6))


def test_loop_checkpoint_restore(tmp_path):
    def step(state, batch):
        return state + 1, float(state)

    batches = iter(range(10_000))
    loop = TrainLoop(TrainLoopConfig(steps=10, ckpt_dir=str(tmp_path),
                                     ckpt_every=5, log_every=100),
                     step, 0, batches)
    loop.run()
    # a fresh loop restores and continues
    loop2 = TrainLoop(TrainLoopConfig(steps=15, ckpt_dir=str(tmp_path),
                                      ckpt_every=5, log_every=100),
                      step, 0, batches)
    assert loop2.start_step == 10
    assert int(loop2.state) == 10
    loop2.run()
    assert int(loop2.state) == 15


def test_rebalancer_min_share_floor_clamps_exactly():
    """Satellite regression: ``penalize`` must clamp the move so the
    penalized host lands exactly ON the floor (never below, never a
    negative move) and the probability mass stays conserved."""
    rb = DataRebalancer(n_hosts=4, min_share=0.5)
    floor = 0.5 / 4
    for _ in range(200):
        rb.penalize(1, factor=0.5)
    assert rb.shares[1] == pytest.approx(floor)
    assert rb.shares.sum() == pytest.approx(1.0)
    assert (rb.shares >= floor - 1e-12).all()
    # a host already at the floor: penalize is a no-op, not a drain
    before = rb.shares.copy()
    rb.penalize(1)
    np.testing.assert_allclose(rb.shares, before)
    # a custom floor of 0 permits full starvation (the old behaviour)
    rb0 = DataRebalancer(n_hosts=2, min_share=0.0)
    for _ in range(400):
        rb0.penalize(0, factor=0.5)
    assert rb0.shares[0] == pytest.approx(0.0, abs=1e-12)


def test_keyboard_interrupt_writes_final_checkpoint(tmp_path):
    """Satellite regression: Ctrl-C used to skip the final checkpoint
    (the save sat after the loop, not in a ``finally``).  A
    KeyboardInterrupt mid-run must leave the last completed step on disk
    and still propagate."""
    from repro.checkpoint import CheckpointManager

    def step(state, batch):
        if state == 7:
            raise KeyboardInterrupt
        return state + 1, float(state)

    loop = TrainLoop(TrainLoopConfig(steps=100, ckpt_dir=str(tmp_path),
                                     ckpt_every=50, log_every=1000),
                     step, 0, iter(range(10_000)))
    with pytest.raises(KeyboardInterrupt):
        loop.run()
    assert CheckpointManager(tmp_path).latest_valid_step() == 7
    # and the resumed loop picks up exactly there
    loop2 = TrainLoop(TrainLoopConfig(steps=100, ckpt_dir=str(tmp_path),
                                      ckpt_every=50, log_every=1000),
                      step, 0, iter(range(10_000)))
    assert loop2.start_step == 7 and int(loop2.state) == 7
