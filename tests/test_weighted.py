"""Weighted bags end-to-end through the hybrid step (HybridDef/DLRMConfig
``weighted=True``): the batch carries per-lookup weights in the idx
layout, the forward computes ``sum(w * row)`` and the sparse update
scales each lookup's cotangent.

Contracts:
* all-ones weights == unweighted, BITWISE (state and loss) — w * 1.0
  multiplies exactly on both the forward and the update path;
* the weighted forward matches a manual weighted-bag computation;
* zero-weighting one slot removes its table's rows from the update
  entirely (bit-exact no-op on those rows) while the unweighted run
  moves them — the backward really is scaled per lookup.
"""

import dataclasses

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp

from repro.core import dlrm as D
from repro.launch.mesh import make_mesh

TABLES = (100, 60, 40, 30, 20, 200, 51, 77)
BASE = D.DLRMConfig(name="t", num_dense=16, bottom=(32, 8), top=(32,),
                    table_rows=TABLES, emb_dim=8, pooling=3, batch=16)


def _mesh():
    return make_mesh((1, 1), ("data", "model"))


def _batch(seed, weights=None):
    rng = np.random.default_rng(seed)
    idx = np.stack([rng.integers(0, max(2, m // 8), (16, 3))
                    for m in TABLES], 1).astype(np.int32)
    b = {"idx": jnp.asarray(idx),
         "dense_x": jnp.asarray(rng.standard_normal((16, 16)), jnp.float32),
         "labels": jnp.asarray(rng.integers(0, 2, 16), jnp.float32)}
    if weights is not None:
        b["weights"] = jnp.asarray(weights, jnp.float32)
    return b


def _emb(state):
    return tuple(np.asarray(v) for v in state["emb"].values())


@pytest.mark.parametrize("mode", ["row", "table"])
def test_all_ones_weights_bitwise_equal_unweighted(mode):
    mesh = _mesh()
    res = {}
    for tag in ("plain", "ones"):
        cfg = dataclasses.replace(BASE, emb_mode=mode,
                                  weighted=(tag == "ones"))
        state, layout = D.init_state(jax.random.PRNGKey(0), cfg, mesh)
        step, _, _, _ = D.make_train_step(cfg, mesh)
        for s in range(2):
            b = _batch(s, weights=(np.ones((16, 8, 3), np.float32)
                                   if tag == "ones" else None))
            state, loss = step(state, b)
        res[tag] = (float(loss), _emb(state))
    assert res["plain"][0] == res["ones"][0]
    for a, b in zip(res["plain"][1], res["ones"][1]):
        assert np.array_equal(a, b)


def test_weighted_forward_matches_manual_bag():
    """eval (serve) path: sigmoid(logits) computed with random weights ==
    the same forward with a manually weighted bag output."""
    mesh = _mesh()
    cfg = dataclasses.replace(BASE, emb_mode="row", weighted=True)
    state, layout = D.init_state(jax.random.PRNGKey(1), cfg, mesh)
    ev, _, _, _ = D.make_eval_step(cfg, mesh)
    rng = np.random.default_rng(2)
    # power-of-two weights: bf16-row * w products are exact in fp32 and a
    # 3-term sum of 8-bit mantissas fits fp32 exactly, so the manual bag
    # is order-independent (no association-rounding flakiness)
    w = rng.choice([0.0, 0.5, 1.0, 2.0], (16, 8, 3)).astype(np.float32)
    b = _batch(2, weights=w)
    got = np.asarray(ev(state, b))

    # manual: weighted bag on the hi table (bf16 wire of the row fwd),
    # then the same dense forward
    hi = np.asarray(state["emb"]["hi"], np.float32)
    g = np.asarray(b["idx"]) + np.asarray(layout.row_offsets,
                                          np.int32)[None, :, None]
    bag = (hi[g] * w[..., None]).sum(axis=2)            # [B, S, E] fp32
    bag = np.asarray(jnp.asarray(bag, jnp.bfloat16), np.float32)
    logits = D.forward_local(state["dense"]["hi"], jnp.asarray(bag),
                             b["dense_x"], cfg.mlp_impl)
    want = np.asarray(jax.nn.sigmoid(logits))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_zero_weight_slot_freezes_its_table():
    """Weights gate the update per lookup: zeroing slot 5's weights leaves
    table 5's rows bit-identical to init after a step, while the same step
    with ones moves them."""
    mesh = _mesh()
    cfg = dataclasses.replace(BASE, emb_mode="row", weighted=True)
    spec = cfg.spec
    lo5, hi5 = (int(spec.row_offsets[5]),
                int(spec.row_offsets[5] + spec.padded_rows[5]))
    touched = {}
    for tag in ("zeroed", "ones"):
        state, layout = D.init_state(jax.random.PRNGKey(0), cfg, mesh)
        init_hi = np.asarray(state["emb"]["hi"], np.float32).copy()
        init_lo = np.asarray(state["emb"]["lo"]).copy()
        step, _, _, _ = D.make_train_step(cfg, mesh)
        w = np.ones((16, 8, 3), np.float32)
        if tag == "zeroed":
            w[:, 5, :] = 0.0
        state, _ = step(state, _batch(0, weights=w))
        hi = np.asarray(state["emb"]["hi"], np.float32)
        lo = np.asarray(state["emb"]["lo"])
        touched[tag] = not (np.array_equal(hi[lo5:hi5], init_hi[lo5:hi5])
                            and np.array_equal(lo[lo5:hi5],
                                               init_lo[lo5:hi5]))
        # other tables always move (weights 1, duplicate-heavy stream)
        assert not np.array_equal(hi[:lo5], init_hi[:lo5])
    assert touched["ones"] and not touched["zeroed"]


def test_weighted_presort_bakes_weights():
    """host_presort + weighted: the loader bakes bag weights into
    psort_wgt and the presorted step tracks the weighted reference step
    (same kernel-vs-reference tolerance as the unweighted fp32 contract;
    the Split-SGD weighted kernel is documented 1-ulp vs pre-scaled)."""
    from repro.data.pipeline import presort_batch
    mesh = _mesh()
    rng = np.random.default_rng(3)
    w = rng.uniform(0.5, 1.5, (16, 8, 3)).astype(np.float32)
    res = {}
    for tag in ("plain", "presort"):
        cfg = dataclasses.replace(BASE, emb_mode="row", weighted=True,
                                  host_presort=(tag == "presort"))
        state, layout = D.init_state(jax.random.PRNGKey(0), cfg, mesh)
        step, _, _, _ = D.make_train_step(cfg, mesh)
        b = _batch(0, weights=w)
        if tag == "presort":
            ps = presort_batch(layout, np.asarray(b["idx"]), w)
            b = {**b, **{k: jnp.asarray(v) for k, v in ps.items()}}
        state, loss = step(state, b)
        res[tag] = (float(loss), _emb(state))
    assert res["plain"][0] == res["presort"][0]
    a_hi, a_lo = res["plain"][1]
    b_hi, b_lo = res["presort"][1]
    from repro.optim.split_sgd import combine_split
    wa = np.asarray(combine_split(jnp.asarray(a_hi, jnp.bfloat16),
                                  jnp.asarray(a_lo)))
    wb = np.asarray(combine_split(jnp.asarray(b_hi, jnp.bfloat16),
                                  jnp.asarray(b_lo)))
    np.testing.assert_allclose(wa, wb, rtol=1e-6, atol=1e-7)


def test_score_step_weighted_and_retrieval_rejects():
    from repro.core import hybrid as H
    from repro.models import recsys as R
    mesh = _mesh()
    mdef = dataclasses.replace(R.make_fm((50,) * 6, batch=8), weighted=True)
    state, layout = H.init_state(jax.random.PRNGKey(0), mdef, mesh)
    sc, _, bspecs, _ = H.make_score_step(mdef, mesh)
    assert "weights" in bspecs and "psort_rows" not in bspecs
    rng = np.random.default_rng(0)
    b = {"idx": jnp.asarray(rng.integers(0, 50, (8, 6, 1)), jnp.int32),
         "labels": jnp.asarray(rng.integers(0, 2, 8), jnp.float32),
         "weights": jnp.asarray(rng.uniform(0.5, 1.5, (8, 6, 1)),
                                jnp.float32)}
    s1 = np.asarray(sc(state, b))
    s2 = np.asarray(sc(state, {**b, "weights": b["weights"] * 2}))
    assert s1.shape == (8,) and not np.array_equal(s1, s2)
    with pytest.raises(ValueError, match="weighted"):
        H.make_retrieval_step(mdef, mesh, n_candidates=8, target_slot=0)
